"""Tests for the traffic substrate (FBT parser + synthetic trace)."""

import pathlib

import numpy as np

from repro.core.coflow import CoflowInstance
from repro.traffic.facebook import (
    load_fbt,
    synthesize_facebook_like,
    to_demands,
)
from repro.traffic.instances import paper_default_instance, sample_instance

FIXTURE = pathlib.Path(__file__).parent / "data" / "tiny.fbt"


def test_fbt_parser_roundtrip(tmp_path):
    path = tmp_path / "trace.fbt"
    path.write_text(
        "150 2\n"
        "0 100 2 5 9 2 3:12.5 7:4.0\n"
        "1 250 1 2 1 8:9.75\n"
    )
    coflows = load_fbt(str(path))
    assert len(coflows) == 2
    assert coflows[0].arrival_ms == 100
    assert list(coflows[0].mappers) == [5, 9]
    assert list(coflows[0].reducers) == [3, 7]
    np.testing.assert_allclose(coflows[0].reducer_mb, [12.5, 4.0])
    assert coflows[1].reducer_mb[0] == 9.75


def test_fbt_fixture_parses_edge_cases():
    """Committed fixture: single-mapper coflow, zero-MB reducer, and
    out-of-order arrival timestamps (file order is NOT arrival order)."""
    coflows = load_fbt(str(FIXTURE))
    assert len(coflows) == 4
    # Parser preserves file order; arrivals are out of order on purpose.
    arrivals = [c.arrival_ms for c in coflows]
    assert arrivals == [0.0, 120.0, 60.0, 45.0]
    assert arrivals != sorted(arrivals)
    # Single-mapper coflow with a zero-MB reducer alongside a real one.
    single = coflows[1]
    assert list(single.mappers) == [3]
    assert list(single.reducers) == [4, 7]
    np.testing.assert_allclose(single.reducer_mb, [0.0, 6.0])


def test_fbt_fixture_to_demands_end_to_end():
    coflows = load_fbt(str(FIXTURE))
    port_map = {m: m for m in range(10)}
    rng = np.random.default_rng(0)
    demands = to_demands(coflows, port_map, 10, rng)
    assert demands.shape == (4, 10, 10)
    # Receiver totals survive the matrix construction.
    for cf, mat in zip(coflows, demands):
        np.testing.assert_allclose(mat.sum(), cf.reducer_mb.sum(), rtol=1e-9)
    # Zero-MB reducer contributes nothing to its column.
    assert demands[1][:, 4].sum() == 0.0
    # Single-mapper coflow: every byte leaves its one sender's row.
    np.testing.assert_allclose(demands[1][3].sum(), 6.0, rtol=1e-9)
    assert np.delete(demands[1], 3, axis=0).sum() == 0.0

    # End-to-end: the parsed trace streams online with its (out-of-order)
    # arrival stamps as releases.
    from repro.experiments import stream

    inst = CoflowInstance(
        demands=demands,
        weights=np.ones(4),
        releases=np.array([c.arrival_ms for c in coflows]),
        rates=np.array([10.0, 20.0]),
        delta=2.0,
    )
    res = stream(inst, lp_method="exact", preempt=False)
    assert (res.finish >= res.arrival).all()
    assert res.num_resolves >= 3  # distinct arrival instants => epochs


def test_synthetic_trace_shape_and_determinism():
    t1 = synthesize_facebook_like(seed=7)
    t2 = synthesize_facebook_like(seed=7)
    assert len(t1) == 526
    np.testing.assert_allclose(t1[10].reducer_mb, t2[10].reducer_mb)
    arrivals = np.array([c.arrival_ms for c in t1])
    assert np.all(np.diff(arrivals) >= 0)
    # Heavy tail: max coflow size >> median.
    sizes = np.array([c.reducer_mb.sum() for c in t1])
    assert sizes.max() > 20 * np.median(sizes)


def test_to_demands_conserves_receiver_totals():
    t = synthesize_facebook_like(num_coflows=20, num_machines=30, seed=1)
    port_map = {m: m for m in range(30)}
    rng = np.random.default_rng(0)
    demands = to_demands(t, port_map, 30, rng)
    for cf, mat in zip(t, demands):
        np.testing.assert_allclose(
            mat.sum(), cf.reducer_mb.sum(), rtol=1e-9
        )
        # Receiver column totals match the trace.
        for rid, mb in zip(cf.reducers, cf.reducer_mb):
            np.testing.assert_allclose(mat[:, rid].sum(), mb, rtol=1e-9)


def test_sample_instance_paper_defaults():
    inst = paper_default_instance(seed=0)
    assert inst.num_coflows == 100
    assert inst.num_ports == 10
    assert inst.num_cores == 3
    assert inst.aggregate_rate == 60.0
    assert inst.delta == 8.0
    assert (inst.demands.sum(axis=(1, 2)) > 0).all()


def test_sample_instance_trace_releases():
    inst = sample_instance(seed=3, release="trace")
    assert (inst.releases >= 0).all()
    assert inst.releases.max() > 0
