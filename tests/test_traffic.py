"""Tests for the traffic substrate (FBT parser + synthetic trace)."""

import numpy as np

from repro.traffic.facebook import (
    load_fbt,
    synthesize_facebook_like,
    to_demands,
)
from repro.traffic.instances import paper_default_instance, sample_instance


def test_fbt_parser_roundtrip(tmp_path):
    path = tmp_path / "trace.fbt"
    path.write_text(
        "150 2\n"
        "0 100 2 5 9 2 3:12.5 7:4.0\n"
        "1 250 1 2 1 8:9.75\n"
    )
    coflows = load_fbt(str(path))
    assert len(coflows) == 2
    assert coflows[0].arrival_ms == 100
    assert list(coflows[0].mappers) == [5, 9]
    assert list(coflows[0].reducers) == [3, 7]
    np.testing.assert_allclose(coflows[0].reducer_mb, [12.5, 4.0])
    assert coflows[1].reducer_mb[0] == 9.75


def test_synthetic_trace_shape_and_determinism():
    t1 = synthesize_facebook_like(seed=7)
    t2 = synthesize_facebook_like(seed=7)
    assert len(t1) == 526
    np.testing.assert_allclose(t1[10].reducer_mb, t2[10].reducer_mb)
    arrivals = np.array([c.arrival_ms for c in t1])
    assert np.all(np.diff(arrivals) >= 0)
    # Heavy tail: max coflow size >> median.
    sizes = np.array([c.reducer_mb.sum() for c in t1])
    assert sizes.max() > 20 * np.median(sizes)


def test_to_demands_conserves_receiver_totals():
    t = synthesize_facebook_like(num_coflows=20, num_machines=30, seed=1)
    port_map = {m: m for m in range(30)}
    rng = np.random.default_rng(0)
    demands = to_demands(t, port_map, 30, rng)
    for cf, mat in zip(t, demands):
        np.testing.assert_allclose(
            mat.sum(), cf.reducer_mb.sum(), rtol=1e-9
        )
        # Receiver column totals match the trace.
        for rid, mb in zip(cf.reducers, cf.reducer_mb):
            np.testing.assert_allclose(mat[:, rid].sum(), mb, rtol=1e-9)


def test_sample_instance_paper_defaults():
    inst = paper_default_instance(seed=0)
    assert inst.num_coflows == 100
    assert inst.num_ports == 10
    assert inst.num_cores == 3
    assert inst.aggregate_rate == 60.0
    assert inst.delta == 8.0
    assert (inst.demands.sum(axis=(1, 2)) > 0).all()


def test_sample_instance_trace_releases():
    inst = sample_instance(seed=3, release="trace")
    assert (inst.releases >= 0).all()
    assert inst.releases.max() > 0
