"""In-place slot-pool `EnsembleBatch` primitive tests.

The resident pool is the ONE sanctioned exemption from the build-once
contract: a single `EnsembleBatch` padded to the pool capacity whose
array *contents* are scatter-updated in place by `update_slots` /
`free_slots` (counted by `SLOT_SCATTER_COUNT`), with per-slot flow
extents managed inside a fixed-capacity arena that grows geometrically
(`SLOT_GROW_COUNT` — the epoch compile-cache bucket ladder).

Contracts under test:

  * scatter fidelity — a populated slot holds exactly the canonical
    flow table (`flows_of`, largest-first), port statistics and global
    lower bound of its coflow, and the demand matrix round-trips
    through the arena bit for bit;
  * no stale leaks — freeing and reusing a slot leaves ZERO residue of
    the previous tenant in ANY array: a pool that saw tenant X, freed
    it, and admitted tenant Y is raw-array-identical to a pool that
    only ever saw Y;
  * empty pools — a fully-freed pool schedules nothing (no valid
    flows, all-zero ccts, empty core schedules);
  * arena lifecycle — extent reuse on shrinking residuals, compaction
    + geometric growth that preserves existing tenants, and the
    build-once / scatter counters;
  * sharded parity — `update_slots` on a forced-8-device mesh build is
    bit-identical to the single-device build (subprocess: XLA_FLAGS
    must precede jax init).
"""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.coflow import flows_of, port_stats
from repro.pipeline import ensemble_batch as eb
from repro.pipeline.batch_alloc import allocate_batch_arrays
from repro.pipeline.batch_circuit import schedule_batch_arrays
from repro.traffic.instances import random_instance

RATES = np.array([10.0, 20.0])
DELTA = 1.5


def _pool(slots=6, num_ports=5, flow_quantum=8, **kw):
    return eb.build_slot_pool_batch(
        slots, num_ports, RATES, DELTA, flow_quantum=flow_quantum, **kw
    )


def _inst(M=3, N=5, seed=0):
    return random_instance(
        num_coflows=M, num_ports=N, num_cores=2, seed=seed
    )


def _slot_demand(pool, slot, num_ports):
    """Reconstruct a slot's demand matrix from the resident flow table."""
    b, r = pool.batch, pool.member
    start = int(pool.flow_start[slot])
    F = int(b.flow_counts[r, slot])
    dem = np.zeros((num_ports, num_ports))
    sl = slice(start, start + F)
    dem[b.flow_src[r, sl], b.flow_dst[r, sl]] = b.flow_size[r, sl]
    return dem


class TestScatterFidelity:
    def test_demands_round_trip_through_arena(self):
        inst = _inst(seed=1)
        pool = _pool()
        slots = np.array([0, 2, 5])
        eb.update_slots(
            pool, slots, inst.demands, inst.weights, inst.releases
        )
        b = pool.batch  # update_slots may regrow: always re-fetch
        for n, s in enumerate(slots):
            assert np.array_equal(
                _slot_demand(pool, int(s), 5), inst.demands[n]
            )
            # Flow table is the canonical largest-first list.
            i_idx, j_idx, sizes = flows_of(
                inst.demands[n], largest_first=True
            )
            sl = slice(
                int(pool.flow_start[s]),
                int(pool.flow_start[s]) + len(sizes),
            )
            assert np.array_equal(b.flow_src[0, sl], i_idx)
            assert np.array_equal(b.flow_dst[0, sl], j_idx)
            assert np.array_equal(b.flow_size[0, sl], sizes)
            assert b.flow_valid[0, sl].all()
            assert (b.flow_coflow[0, sl] == s).all()
            # Port stats + per-slot lower bound match the oracle math.
            rho, tau = port_stats(inst.demands[n])
            assert np.array_equal(
                b.lp_rho[0, s], rho[0].astype(np.float32)
            )
            assert np.array_equal(
                b.lp_tau[0, s], tau[0].astype(np.float32)
            )
            assert b.glb[0, s] == DELTA + rho[0].max() / RATES.sum()
        assert np.array_equal(b.weights[0, slots], inst.weights)
        assert np.array_equal(b.releases[0, slots], inst.releases)
        assert b.coflow_mask[0, slots].all()
        # Untouched slots stay free and masked.
        others = np.setdiff1d(np.arange(6), slots)
        assert not b.coflow_mask[0, others].any()
        assert (pool.flow_start[others] == -1).all()

    def test_build_counts_once_and_scatters_count(self):
        before_build = eb.BUILD_COUNT
        before_scatter = eb.SLOT_SCATTER_COUNT
        pool = _pool()
        assert eb.BUILD_COUNT == before_build + 1
        inst = _inst(seed=2)
        eb.update_slots(
            pool, np.array([1, 3, 4]), inst.demands, inst.weights,
            inst.releases,
        )
        eb.free_slots(pool, np.array([3]))
        assert eb.BUILD_COUNT == before_build + 1  # still ONE build
        assert eb.SLOT_SCATTER_COUNT == before_scatter + 2

    def test_pool_validation(self):
        with pytest.raises(ValueError):
            _pool(slots=0)
        with pytest.raises(ValueError):
            _pool(flow_quantum=0)


class TestStaleLeaks:
    def _assert_batches_identical(self, pa, pb):
        for f in dataclasses.fields(eb.EnsembleBatch):
            if f.metadata.get("static"):
                continue
            a = np.asarray(getattr(pa.batch, f.name))
            b = np.asarray(getattr(pb.batch, f.name))
            assert np.array_equal(a, b), f.name
        assert np.array_equal(pa.flow_start, pb.flow_start)
        assert np.array_equal(pa.flow_cap, pb.flow_cap)

    def test_slot_reuse_leaves_no_residue(self):
        """free + readmit == never-saw-the-first-tenant, raw arrays."""
        x, y = _inst(seed=3), _inst(seed=4)
        bystander = _inst(M=1, seed=5)

        pool_a = _pool()
        # Bystander pins slot 0 so the arena layout is nontrivial.
        eb.update_slots(
            pool_a, np.array([0]), bystander.demands,
            bystander.weights, bystander.releases,
        )
        eb.update_slots(
            pool_a, np.array([2, 3, 4]), x.demands, x.weights, x.releases
        )
        eb.free_slots(pool_a, np.array([2, 3, 4]))
        eb.update_slots(
            pool_a, np.array([2, 3, 4]), y.demands, y.weights, y.releases
        )

        pool_b = _pool()
        eb.update_slots(
            pool_b, np.array([0]), bystander.demands,
            bystander.weights, bystander.releases,
        )
        eb.update_slots(
            pool_b, np.array([2, 3, 4]), y.demands, y.weights, y.releases
        )
        self._assert_batches_identical(pool_a, pool_b)

    def test_free_zeroes_every_per_slot_field(self):
        inst = _inst(seed=6)
        pool = _pool()
        slots = np.array([1, 2, 3])
        eb.update_slots(
            pool, slots, inst.demands, inst.weights, inst.releases
        )
        eb.free_slots(pool, slots)
        b = pool.batch
        assert not b.coflow_mask[0].any()
        assert not b.flow_valid[0].any()
        for arr in (
            b.weights, b.releases, b.glb, b.lp_weights, b.lp_releases,
            b.flow_size, b.flow_counts,
        ):
            assert not np.asarray(arr[0]).any()
        assert not b.lp_rho[0].any() and not b.lp_tau[0].any()
        assert (pool.flow_start == -1).all()
        assert (pool.flow_cap == 0).all()


class TestEmptyPool:
    def test_fully_freed_pool_schedules_nothing(self):
        inst = _inst(seed=7)
        pool = _pool()
        slots = np.array([0, 1, 2])
        eb.update_slots(
            pool, slots, inst.demands, inst.weights, inst.releases
        )
        eb.free_slots(pool, slots)
        b = pool.batch
        orders = np.arange(b.pad_coflows, dtype=np.int64)[None, :]
        alloc = allocate_batch_arrays(b, orders)
        pairs = schedule_batch_arrays(b, alloc, "greedy")
        schedules, ccts = pairs[0]
        assert not np.asarray(alloc.valid[0]).any()
        assert not np.asarray(ccts).any()
        for cs in schedules:
            assert cs.coflow.size == 0


class TestArenaLifecycle:
    def test_shrinking_residual_reuses_extent_in_place(self):
        inst = _inst(M=1, seed=8)
        pool = _pool()
        eb.update_slots(
            pool, np.array([2]), inst.demands, inst.weights, inst.releases
        )
        start, cap = int(pool.flow_start[2]), int(pool.flow_cap[2])
        grow_before = eb.SLOT_GROW_COUNT
        # Drop half the flows (a preemption residual) and rescatter.
        resid = inst.demands.copy()
        i_idx, j_idx, _ = flows_of(resid[0], largest_first=True)
        resid[0, i_idx[::2], j_idx[::2]] = 0.0
        eb.update_slots(
            pool, np.array([2]), resid, inst.weights, inst.releases
        )
        b = pool.batch
        assert int(pool.flow_start[2]) == start  # same extent
        assert int(pool.flow_cap[2]) == cap
        assert eb.SLOT_GROW_COUNT == grow_before
        F = int(b.flow_counts[0, 2])
        assert not b.flow_valid[0, start + F:start + cap].any()
        assert not b.flow_size[0, start + F:start + cap].any()
        assert np.array_equal(_slot_demand(pool, 2, 5), resid[0])

    def test_growth_is_geometric_and_preserves_tenants(self):
        # quantum 4 but instances carry ~N^2 flows each: the arena must
        # grow, and each growth at least doubles capacity.
        pool = _pool(flow_quantum=4)
        grow_before = eb.SLOT_GROW_COUNT
        caps = [pool.flow_capacity]
        insts = [_inst(M=1, N=5, seed=10 + s) for s in range(4)]
        for s, inst in enumerate(insts):
            eb.update_slots(
                pool, np.array([s]), inst.demands, inst.weights,
                inst.releases,
            )
            caps.append(pool.flow_capacity)
        assert eb.SLOT_GROW_COUNT > grow_before
        for a, b in zip(caps, caps[1:]):
            assert b == a or b >= 2 * a  # geometric ladder
            assert b % 4 == 0  # quantized
        # Growth/compaction never corrupted earlier tenants.
        for s, inst in enumerate(insts):
            assert np.array_equal(_slot_demand(pool, s, 5), inst.demands[0])

    def test_compaction_packs_before_growing(self):
        # Fill two slots, free the first (leaving a leading gap), then
        # admit a tenant that fits total-free but not any single gap:
        # the arena must compact instead of growing.
        pool = _pool(slots=4, num_ports=4, flow_quantum=10)
        a, b_, c = (_inst(M=1, N=4, seed=20 + s) for s in range(3))
        for s, inst in ((0, a), (1, b_)):
            eb.update_slots(
                pool, np.array([s]), inst.demands, inst.weights,
                inst.releases,
            )
        cap0 = pool.flow_capacity
        eb.free_slots(pool, np.array([0]))
        grow_before = eb.SLOT_GROW_COUNT
        eb.update_slots(
            pool, np.array([2]), c.demands, c.weights, c.releases
        )
        free_total = cap0 - int(
            pool.flow_cap[pool.flow_start >= 0].sum()
        )
        if free_total >= 0 and pool.flow_capacity == cap0:
            assert eb.SLOT_GROW_COUNT == grow_before
        # Surviving tenants intact either way.
        assert np.array_equal(_slot_demand(pool, 1, 4), b_.demands[0])
        assert np.array_equal(_slot_demand(pool, 2, 4), c.demands[0])


_SHARD_SCRIPT = r"""
import dataclasses
import numpy as np
import jax

assert len(jax.devices()) == 8, jax.devices()

from repro.launch.mesh import make_local_mesh
from repro.pipeline import ensemble_batch as eb
from repro.traffic.instances import random_instance

insts = [
    random_instance(num_coflows=3, num_ports=5, num_cores=2, seed=s)
    for s in (0, 1)
]
rates = np.array([10.0, 20.0])


def fill(pool):
    eb.update_slots(pool, np.array([0, 2, 4]), insts[0].demands,
                    insts[0].weights, insts[0].releases)
    eb.free_slots(pool, np.array([2]))
    eb.update_slots(pool, np.array([2, 3, 5]), insts[1].demands,
                    insts[1].weights, insts[1].releases)
    return pool


single = fill(eb.build_slot_pool_batch(6, 5, rates, 1.5, flow_quantum=8))
sharded = fill(eb.build_slot_pool_batch(6, 5, rates, 1.5, flow_quantum=8,
                                        mesh=make_local_mesh()))
assert sharded.batch.sharding is not None
assert sharded.batch.pad_members % 8 == 0

for f in dataclasses.fields(eb.EnsembleBatch):
    if f.metadata.get("static"):
        continue
    a = np.asarray(getattr(single.batch, f.name))
    b = np.asarray(getattr(sharded.batch, f.name))
    # Every array carries a leading member axis; the live member is
    # row 0 and must match the single-device build bit for bit.
    assert np.array_equal(a[0], b[0]), f.name
# Sharding pad rows never claim coflows or flows.
assert not np.asarray(sharded.batch.coflow_mask)[1:].any()
assert not np.asarray(sharded.batch.flow_valid)[1:].any()
assert np.array_equal(single.flow_start, sharded.flow_start)
assert np.array_equal(single.flow_cap, sharded.flow_cap)
print("SLOT-POOL-SHARD-OK")
"""


def test_update_slots_sharded_matches_single_device(tmp_path):
    """Forced 8-device mesh build vs single-device: bit-for-bit."""
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        capture_output=True,
        text=True,
        timeout=900,
        # Inherit the environment: a minimal env (no HOME) can stall
        # CPython startup for minutes on some hosts.
        env={
            **os.environ,
            "PYTHONPATH": "src",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "REPRO_RESULTS": str(tmp_path),
        },
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SLOT-POOL-SHARD-OK" in proc.stdout
