"""Content-addressed sweep cache tests (ISSUE acceptance criteria).

The cache is a pure memo over sweep cells: a cell key is the canonical
hash of (instance bits, scheme spec, pipeline/engine config, code
fingerprint), a hit short-circuits the batched pipeline entirely, and
cached runs must export **byte-identical** artifacts to fresh ones.

  * key sensitivity — identical specs collide, any perturbation of
    demands/weights/releases/rates/delta, scheme, config knob or code
    fingerprint separates;
  * sweep integration — replay computes zero cells (hit counters
    asserted), perturbing one instance recomputes exactly that
    instance's cells, adding a scheme recomputes only the new column;
  * persistence — the manifest survives a restart (new `SweepCache` on
    the same root serves hits), and missing object files self-heal as
    misses;
  * byte identity — JSON + CSV files written from cached rows equal the
    fresh run's bytes exactly.
"""

import json
import os

import numpy as np
import pytest

from repro.experiments import SweepCache, code_fingerprint, sweep
from repro.experiments.cache import (
    canonical_digest,
    cell_key,
    instance_digest,
    scheme_digest,
)
from repro.experiments.results import save_rows
from repro.traffic.instances import random_instance


def _ens(n=3, seed0=40):
    return [
        random_instance(
            num_coflows=8 + 2 * s, num_ports=4, num_cores=2, seed=seed0 + s
        )
        for s in range(n)
    ]


_KW = dict(schemes=("ours", "wspt_order"), lp_method="exact", validate=False)


class TestDigests:
    def test_instance_digest_deterministic(self):
        a, b = random_instance(seed=5), random_instance(seed=5)
        assert instance_digest(a) == instance_digest(b)

    @pytest.mark.parametrize(
        "field", ["demands", "weights", "releases", "rates", "delta"]
    )
    def test_instance_digest_sensitive(self, field):
        import dataclasses

        inst = random_instance(seed=5)
        if field == "delta":
            other = dataclasses.replace(inst, delta=inst.delta + 1.0)
        else:
            arr = np.array(getattr(inst, field), copy=True)
            arr.flat[0] += 1.0
            other = dataclasses.replace(inst, **{field: arr})
        assert instance_digest(inst) != instance_digest(other)

    def test_scheme_digest_separates_schemes(self):
        assert scheme_digest("ours") != scheme_digest("wspt_order")

    def test_config_digest_sensitive(self):
        base = dict(
            lp_method="exact", lp_iters=100, m_quantum=8, p_quantum=8,
            discipline="greedy", alloc="batch", circuit="batch",
            circuit_engine="auto", certify=False,
        )
        d0 = canonical_digest(base)
        assert d0 == canonical_digest(dict(base))
        for k, v in [("lp_iters", 200), ("discipline", "reserving"),
                     ("circuit_engine", "kernel"), ("certify", True)]:
            assert canonical_digest({**base, k: v}) != d0

    def test_cell_key_mixes_all_parts(self):
        parts = ["i", "s", "c", "f"]
        k0 = cell_key(*parts)
        for j in range(4):
            p = list(parts)
            p[j] = "x"
            assert cell_key(*p) != k0
        assert len(k0) == 64  # sha256 hex

    def test_code_fingerprint_stable_in_process(self):
        assert code_fingerprint() == code_fingerprint()


class TestStore:
    def test_put_get_round_trip(self, tmp_path):
        c = SweepCache(tmp_path)
        payload = {"total_weighted_cct": 12.5, "ccts": [1.0, 2.5]}
        c.put("k" * 64, payload)
        c.flush()
        assert SweepCache(tmp_path).get("k" * 64) == payload

    def test_get_missing_is_none(self, tmp_path):
        assert SweepCache(tmp_path).get("a" * 64) is None

    def test_missing_object_self_heals(self, tmp_path):
        c = SweepCache(tmp_path)
        c.put("b" * 64, {"x": 1})
        c.flush()
        obj = next((tmp_path / "objects").rglob("*.json"))
        obj.unlink()
        assert SweepCache(tmp_path).get("b" * 64) is None

    def test_manifest_merges_concurrent_writers(self, tmp_path):
        # Two handles on one root (the sharded-runner pattern): both
        # flush; neither clobbers the other's entries.
        c1, c2 = SweepCache(tmp_path), SweepCache(tmp_path)
        c1.put("c" * 64, {"x": 1})
        c2.put("d" * 64, {"y": 2})
        c1.flush()
        c2.flush()
        c3 = SweepCache(tmp_path)
        assert c3.get("c" * 64) == {"x": 1}
        assert c3.get("d" * 64) == {"y": 2}


class TestGc:
    """`SweepCache.gc`: LRU eviction + self-healing manifest rewrite."""

    @staticmethod
    def _fill(c, n, size=100):
        # Distinct keys with strictly increasing LRU stamps (the wall
        # clock's 1 s resolution would tie within a fast test run).
        keys = [format(i, "x") * 32 for i in range(n)]
        for i, k in enumerate(keys):
            c.put(k, {"blob": "x" * size})
            c._manifest[k]["created"] = f"2026-01-01T00:00:{i:02d}Z"
        return keys

    def test_noop_under_budget(self, tmp_path):
        c = SweepCache(tmp_path)
        keys = self._fill(c, 3)
        stats = c.gc(max_bytes=10**9)
        assert stats["evicted"] == 0 and stats["kept"] == 3
        assert all(c.get(k) is not None for k in keys)

    def test_evicts_oldest_first(self, tmp_path):
        c = SweepCache(tmp_path)
        keys = self._fill(c, 4)
        sz = os.path.getsize(c._object_path(keys[0]))
        stats = c.gc(max_bytes=2 * sz)
        assert stats["evicted"] == 2 and stats["bytes"] <= 2 * sz
        assert c.get(keys[0]) is None and c.get(keys[1]) is None
        assert c.get(keys[2]) is not None and c.get(keys[3]) is not None
        assert not os.path.exists(c._object_path(keys[0]))

    def test_hit_refreshes_lru_rank(self, tmp_path):
        c = SweepCache(tmp_path)
        keys = self._fill(c, 3)
        assert c.get(keys[0]) is not None  # stamps "accessed" = now
        sz = os.path.getsize(c._object_path(keys[0]))
        c.gc(max_bytes=sz)
        # keys[1] (oldest untouched) went first; the re-read oldest
        # cell was promoted to most-recent and survives to the end.
        assert c.get(keys[0]) is not None
        assert c.get(keys[1]) is None and c.get(keys[2]) is None

    def test_max_cells_budget(self, tmp_path):
        c = SweepCache(tmp_path)
        keys = self._fill(c, 5)
        stats = c.gc(max_cells=2)
        assert stats["kept"] == 2
        assert [k for k in keys if c.get(k) is not None] == keys[3:]

    def test_heals_dangling_entries(self, tmp_path):
        c = SweepCache(tmp_path)
        keys = self._fill(c, 3)
        os.remove(c._object_path(keys[1]))
        stats = c.gc()  # no budgets: pure self-heal pass
        assert stats == {
            "scanned": 3, "kept": 2, "evicted": 0, "healed": 1,
            "freed_bytes": 0, "bytes": stats["bytes"],
        }
        doc = json.loads((tmp_path / "manifest.json").read_text())
        assert sorted(doc["cells"]) == sorted([keys[0], keys[2]])

    def test_eviction_survives_restart(self, tmp_path):
        # The rewrite must NOT merge with the stale on-disk manifest:
        # evicted cells stay gone for a fresh handle on the same root.
        c = SweepCache(tmp_path)
        keys = self._fill(c, 4)
        c.flush()
        c.gc(max_cells=1)
        c2 = SweepCache(tmp_path)
        assert len(c2) == 1
        assert c2.get(keys[3]) is not None
        assert all(c2.get(k) is None for k in keys[:3])

    def test_gc_merges_unflushed_disk_entries(self, tmp_path):
        # Another worker's flushed cells are visible to gc even when this
        # handle never loaded them.
        other = SweepCache(tmp_path)
        other.put("e" * 64, {"x": 1})
        other.flush()
        c = SweepCache(tmp_path)
        c._manifest = {}  # simulate a handle opened before other's flush
        stats = c.gc(max_bytes=10**9)
        assert stats["kept"] == 1


class TestSweepIntegration:
    def test_replay_computes_zero_cells(self, tmp_path):
        ens = _ens()
        fresh = sweep(ens, cache=str(tmp_path), **_KW)
        assert fresh.cache_stats["computed"] == fresh.cache_stats["cells"] == 6
        replay = sweep(ens, cache=str(tmp_path), **_KW)
        assert replay.cache_stats["computed"] == 0
        assert replay.cache_stats["hits"] == 6

    def test_restart_serves_hits(self, tmp_path):
        ens = _ens()
        sweep(ens, cache=SweepCache(tmp_path), **_KW)
        replay = sweep(ens, cache=SweepCache(tmp_path), **_KW)
        assert replay.cache_stats["computed"] == 0

    def test_perturbed_instance_recomputes_only_its_cells(self, tmp_path):
        import dataclasses

        ens = _ens()
        sweep(ens, cache=str(tmp_path), **_KW)
        w = np.array(ens[1].weights, copy=True)
        w[0] += 1.0
        ens[1] = dataclasses.replace(ens[1], weights=w)
        res = sweep(ens, cache=str(tmp_path), **_KW)
        # 2 schemes x 1 perturbed instance.
        assert res.cache_stats == {
            "cells": 6, "hits": 4, "misses": 2, "computed": 2
        }

    def test_added_scheme_recomputes_only_new_column(self, tmp_path):
        ens = _ens()
        sweep(ens, cache=str(tmp_path), **_KW)
        res = sweep(
            ens,
            cache=str(tmp_path),
            **{**_KW, "schemes": ("ours", "wspt_order", "load_only")},
        )
        assert res.cache_stats["hits"] == 6
        assert res.cache_stats["computed"] == 3

    def test_config_change_invalidates(self, tmp_path):
        ens = _ens(2)
        sweep(ens, cache=str(tmp_path), **_KW)
        res = sweep(ens, cache=str(tmp_path), **{**_KW, "discipline": "reserving"})
        assert res.cache_stats["hits"] == 0

    def test_fingerprint_change_invalidates(self, tmp_path):
        ens = _ens(2)
        sweep(ens, cache=str(tmp_path), **_KW)
        stale = SweepCache(tmp_path, fingerprint="deadbeef")
        res = sweep(ens, cache=stale, **_KW)
        assert res.cache_stats["hits"] == 0
        assert res.cache_stats["computed"] == 4

    def test_rows_byte_identical(self, tmp_path):
        ens = _ens()
        plain = sweep(ens, **_KW)
        fresh = sweep(ens, cache=str(tmp_path), **_KW)
        replay = sweep(ens, cache=str(tmp_path), **_KW)
        blobs = {
            json.dumps(r.rows(), default=float)
            for r in (plain, fresh, replay)
        }
        assert len(blobs) == 1

    def test_artifact_files_byte_identical(self, tmp_path, monkeypatch):
        ens = _ens(2)
        out = tmp_path / "results"
        monkeypatch.setenv("REPRO_RESULTS", str(out))
        plain = sweep(ens, **_KW)
        save_rows("parity_fresh", plain.rows())
        replay = sweep(ens, cache=str(tmp_path / "cache"), **_KW)
        replay = sweep(ens, cache=str(tmp_path / "cache"), **_KW)
        assert replay.cache_stats["computed"] == 0
        save_rows("parity_replay", replay.rows())
        for ext in ("json", "csv"):
            a = (out / f"parity_fresh.{ext}").read_bytes()
            b = (out / f"parity_replay.{ext}").read_bytes()
            assert a.replace(b"parity_fresh", b"X") == b.replace(
                b"parity_replay", b"X"
            )

    def test_certified_sweep_caches_cert_fields(self, tmp_path):
        ens = _ens(2)
        kw = dict(schemes=("ours",), lp_method="exact", validate=False,
                  certify=True)
        fresh = sweep(ens, cache=str(tmp_path), **kw)
        replay = sweep(ens, cache=str(tmp_path), **kw)
        assert replay.cache_stats["computed"] == 0
        assert json.dumps(fresh.rows(), default=float) == json.dumps(
            replay.rows(), default=float
        )
        for row in replay.rows():
            if row["scheme"] == "ours":
                assert row["approx_ratio"] <= row["bound"] + 1e-9

    def test_certify_without_ours_rejected(self, tmp_path):
        with pytest.raises(ValueError):
            sweep(
                _ens(1),
                cache=str(tmp_path),
                schemes=("wspt_order",),
                lp_method="exact",
                certify=True,
            )
