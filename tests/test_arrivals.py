"""Arrival-process generator tests: seeded determinism + coarse
distribution checks, sized to stay fast in CI (a few thousand draws)."""

import numpy as np
import pytest

from repro.core.coflow import CoflowInstance
from repro.traffic.arrivals import (
    diurnal_arrivals,
    onoff_arrivals,
    periodic_waves,
    poisson_arrivals,
    with_releases,
)
from repro.traffic.instances import random_instance

GENERATORS = [
    lambda n, seed: poisson_arrivals(n, seed=seed),
    lambda n, seed: onoff_arrivals(n, seed=seed),
    lambda n, seed: diurnal_arrivals(n, seed=seed),
    lambda n, seed: periodic_waves(n, seed=seed),
]


@pytest.mark.parametrize("gen", GENERATORS)
def test_generators_are_seed_deterministic_sorted_nonnegative(gen):
    a = gen(200, 7)
    b = gen(200, 7)
    c = gen(200, 8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)  # seed actually matters
    assert a.shape == (200,)
    assert 0.0 <= a[0] < a[-1]  # starts at/near zero
    assert (np.diff(a) >= 0).all()
    assert gen(0, 0).shape == (0,)


def test_poisson_interarrival_mean():
    a = poisson_arrivals(4000, mean_interarrival_ms=250.0, seed=3)
    gaps = np.diff(a)
    # Exponential(250) mean within 10% at n=4000.
    assert abs(gaps.mean() - 250.0) / 250.0 < 0.10
    # Memoryless: coefficient of variation ~ 1.
    assert abs(gaps.std() / gaps.mean() - 1.0) < 0.15


def test_onoff_burstiness_ratio():
    # ON arrivals every ~50ms, OFF gaps ~20x the ON sojourn: the process
    # must be much burstier than Poisson — most gaps small, a heavy tail
    # of long silences, and a peak-to-mean rate ratio near
    # (mean_on + mean_off) / mean_on = 11.
    a = onoff_arrivals(
        4000, mean_on_ms=1000.0, mean_off_ms=10_000.0,
        mean_interarrival_on_ms=50.0, seed=5,
    )
    gaps = np.diff(a)
    burstiness = gaps.mean() / np.median(gaps)
    assert burstiness > 3.0  # Poisson has mean/median ~ 1.44
    # Long-run rate is dominated by OFF periods.
    assert gaps.mean() > 3 * 50.0
    # Coefficient of variation far above the Poisson value of 1.
    assert gaps.std() / gaps.mean() > 2.0


def test_diurnal_rate_modulation():
    # With a strong diurnal depth, arrivals concentrate in the "day"
    # half-period (sin > 0) and thin out at "night".
    period = 20_000.0
    a = diurnal_arrivals(
        6000, period_ms=period, mean_interarrival_ms=20.0,
        depth=0.9, seed=2,
    )
    phase = np.mod(a, period) / period
    day = ((phase > 0.0) & (phase < 0.5)).sum()
    night = ((phase >= 0.5) & (phase < 1.0)).sum()
    assert day > 1.5 * night
    with pytest.raises(ValueError):
        diurnal_arrivals(10, depth=1.5)


def test_periodic_waves_structure():
    a = periodic_waves(
        64, period_ms=1000.0, wave_size=8, jitter_ms=10.0, seed=1
    )
    # 64 coflows in 8 waves of 8, each within its jitter window.
    wave = np.floor_divide(a, 1000.0)
    counts = np.bincount(wave.astype(int), minlength=8)
    assert (counts == 8).all()
    within = np.mod(a, 1000.0)
    assert within.max() < 10.0 + 1e-9
    with pytest.raises(ValueError):
        periodic_waves(10, wave_size=0)


def test_with_releases_stamps_and_validates():
    inst = random_instance(num_coflows=6, num_ports=3, num_cores=2, seed=0)
    arr = poisson_arrivals(6, mean_interarrival_ms=100.0, seed=4)
    out = with_releases(inst, arr)
    assert isinstance(out, CoflowInstance)
    np.testing.assert_array_equal(out.releases, arr)
    np.testing.assert_array_equal(out.demands, inst.demands)
    assert np.array_equal(inst.releases, np.zeros(6))  # original untouched
    with pytest.raises(ValueError):
        with_releases(inst, arr[:-1])
