"""Tests for the coflow-aware collective planner (paper -> framework)."""

import jax
import numpy as np
import pytest

from repro.collectives.planner import (
    GradientBucket,
    _a2a_demand,
    _ring_demand,
    buckets_from_params,
    plan,
)


def test_ring_demand_conservation():
    for P in (2, 4, 8):
        d = _ring_demand(P, 100.0)
        # Every pod ships 2(P-1)/P of the bucket to its neighbour.
        np.testing.assert_allclose(d.sum(axis=1), 2 * (P - 1) / P * 100.0)
        assert (np.diag(d) == 0).all()


def test_a2a_demand():
    d = _a2a_demand(4, 160.0)
    assert (np.diag(d) == 0).all()
    np.testing.assert_allclose(d[0, 1], 10.0)


def test_buckets_from_params():
    from repro.configs import get_arch
    from repro.models.model import build_model

    model = build_model(get_arch("gemma3-1b").reduced())
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    buckets = buckets_from_params(shapes, bucket_bytes=64 << 10)
    total = sum(b.bytes for b in buckets)
    expect = sum(x.size * 2 for x in jax.tree.leaves(shapes))
    assert total == expect
    fr = [b.layer_frac for b in buckets]
    assert fr == sorted(fr) and 0.0 <= fr[0] and fr[-1] == 1.0


def test_plan_beats_or_matches_fifo():
    buckets = [
        GradientBucket(f"b{i}", (8 + 24 * (i % 3)) << 20, i / 11)
        for i in range(12)
    ]
    p = plan(buckets, num_pods=4, plane_rates_gbps=(25.0, 50.0, 100.0))
    # Weighted CCT under Algorithm 1 should not lose badly to FIFO, and the
    # plan must schedule every flow exactly once.
    assert p.total_weighted_ours <= 1.1 * p.total_weighted_fifo
    n_flows = sum(len(v) for v in p.plane_of_flow.values())
    expect = int((p.instance.demands > 0).sum())
    assert n_flows == expect
    assert set(p.order) == {b.name for b in buckets}


def test_plan_with_a2a_buckets():
    buckets = [GradientBucket(f"b{i}", 32 << 20, i / 3) for i in range(4)]
    a2a = [GradientBucket(f"a2a{i}", 16 << 20, i / 3) for i in range(2)]
    p = plan(buckets, num_pods=4, a2a_buckets=a2a)
    assert "a2a0" in p.order and "a2a1" in p.order
    # a2a flows exist between every distinct pod pair.
    flows = p.plane_of_flow["a2a0"]
    pairs = {(s, d) for s, d, _, _ in flows}
    assert len(pairs) == 12  # 4*3 ordered pairs


def test_plan_respects_release_times():
    buckets = [GradientBucket(f"b{i}", 64 << 20, i / 4) for i in range(5)]
    p = plan(buckets, num_pods=2, backward_ms=50.0)
    rel = p.instance.releases
    for k, cs_flows in enumerate(p.plane_of_flow.values()):
        for _, _, _, t in cs_flows:
            pass  # establishment times validated inside scheduler.run
    # Deeper buckets (layer_frac ~ 1) release first.
    assert rel[-1] == 0.0 or rel[0] >= rel[-1]
