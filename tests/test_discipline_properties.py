"""Property tests for the intra-core scheduling disciplines' invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import lp, scheduler
from repro.core.coflow import CoflowInstance


@st.composite
def instances(draw):
    M = draw(st.integers(2, 7))
    N = draw(st.integers(2, 4))
    K = draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    demands = np.where(
        rng.random((M, N, N)) < 0.5, rng.uniform(1.0, 30.0, (M, N, N)), 0.0
    )
    for m in range(M):
        if demands[m].sum() == 0:
            demands[m, rng.integers(N), rng.integers(N)] = rng.uniform(1, 30)
    return CoflowInstance(
        demands=demands,
        weights=rng.uniform(0.5, 5.0, M),
        releases=rng.uniform(0, 20.0, M) if draw(st.booleans()) else np.zeros(M),
        rates=rng.uniform(5.0, 25.0, K),
        delta=draw(st.sampled_from([0.0, 2.0, 8.0])),
    )


def _events(cs):
    """(establish, complete, coflow, src, dst) rows sorted by establish."""
    order = np.argsort(cs.establish, kind="stable")
    return [
        (cs.establish[f], cs.complete[f], cs.coflow[f], cs.src[f], cs.dst[f])
        for f in order
    ]


@settings(max_examples=20, deadline=None)
@given(instances())
def test_reserving_no_priority_inversion_on_ports(inst):
    """Reserving discipline invariant: when a lower-priority subflow
    establishes at time t, no higher-priority *released, unscheduled*
    subflow shares either of its ports at t."""
    sol = lp.solve_exact(inst)
    res = scheduler.run(inst, "ours", lp_solution=sol, discipline="reserving")
    pos = np.empty(inst.num_coflows, dtype=np.int64)
    pos[res.order] = np.arange(inst.num_coflows)
    for cs in res.core_schedules:
        F = len(cs.coflow)
        for f in range(F):
            t = cs.establish[f]
            for g in range(F):
                if g == f or cs.establish[g] <= t:  # started earlier: fine
                    continue
                higher = pos[cs.coflow[g]] < pos[cs.coflow[f]]
                released = inst.releases[cs.coflow[g]] <= t
                shares = cs.src[g] == cs.src[f] or cs.dst[g] == cs.dst[f]
                if higher and released and shares:
                    # g must have been blocked by a BUSY port at t (not
                    # merely by f's own establishment).
                    busy = False
                    for h in range(F):
                        if h == g or cs.establish[h] >= t or h == f:
                            continue
                        if cs.complete[h] > t and (
                            cs.src[h] == cs.src[g] or cs.dst[h] == cs.dst[g]
                        ):
                            busy = True
                            break
                    assert busy, (
                        f"priority inversion: flow of coflow {cs.coflow[f]} "
                        f"started at {t} while higher-priority released flow "
                        f"of coflow {cs.coflow[g]} shared a free port"
                    )


@settings(max_examples=20, deadline=None)
@given(instances())
def test_greedy_no_idle_eligible_pair(inst):
    """Greedy discipline invariant (the Lemma-5 'no idle pair' step): at
    every establishment time t, any released unscheduled subflow with both
    ports idle must itself establish at t."""
    sol = lp.solve_exact(inst)
    res = scheduler.run(inst, "ours", lp_solution=sol, discipline="greedy")
    for cs in res.core_schedules:
        F = len(cs.coflow)
        times = sorted(set(np.asarray(cs.establish).tolist()))
        for t in times:
            for g in range(F):
                if cs.establish[g] <= t or inst.releases[cs.coflow[g]] > t:
                    continue
                # Is either port of g busy at t (by flows established < t,
                # or establishing exactly at t)?
                busy = any(
                    cs.establish[h] <= t < cs.complete[h]
                    and (cs.src[h] == cs.src[g] or cs.dst[h] == cs.dst[g])
                    for h in range(F)
                    if h != g
                )
                assert busy, (
                    f"work-conservation violated: flow of coflow "
                    f"{cs.coflow[g]} eligible at {t} but establishes later"
                )


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 7))
def test_bvn_decomposition_properties(seed, n):
    """BvN: stuffing preserves entries; decomposition reconstructs the
    stuffed matrix from positive-coefficient permutations."""
    from repro.core.bvn import bvn_decompose, stuff_to_constant_line_sums

    rng = np.random.default_rng(seed)
    m = np.where(rng.random((n, n)) < 0.6, rng.uniform(0.5, 9.0, (n, n)), 0.0)
    s = stuff_to_constant_line_sums(m)
    assert np.all(s >= m - 1e-12)
    target = s.sum(axis=1)
    np.testing.assert_allclose(target, target[0], rtol=1e-9)
    recon = np.zeros_like(s)
    for coef, perm in bvn_decompose(s):
        assert coef > 0
        assert sorted(perm.tolist()) == list(range(n))
        recon[np.arange(n), perm] += coef
    np.testing.assert_allclose(recon, s, atol=1e-6)
