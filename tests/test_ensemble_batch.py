"""Tests for the unified `EnsembleBatch` pytree and the array pipeline.

Covers the one-build-per-ensemble contract (the stage-boundary
`BUILD_COUNT`), the canonical-flow-table permutation against the
host-side `flow_sequence` oracle, batched ordering parity for all three
order stages, the direct LP-batch -> ordering feed, the stage_cache
ensemble-fingerprint guard, and degenerate (M=0 / empty) ensembles
through bucketing, the LP phase and the full pipeline.
"""


import numpy as np
import pytest

from repro import pipeline
from repro.core import lp
from repro.core.coflow import CoflowInstance
from repro.core.ordering import fifo_order, wspt_order
from repro.pipeline import ensemble_batch as eb
from repro.pipeline.batch_alloc import allocate_batch_arrays, flow_sequence
from repro.pipeline.batch_circuit import schedule_batch, schedule_batch_arrays
from repro.traffic.instances import random_instance

GRID = [(5, 3, 2, 0), (8, 4, 3, 1), (10, 4, 4, 2), (6, 5, 2, 3)]


def _grid_instances():
    return [
        random_instance(num_coflows=M, num_ports=N, num_cores=K, seed=seed)
        for M, N, K, seed in GRID
    ]


@pytest.fixture(scope="module")
def grid_with_lp():
    instances = _grid_instances()
    return instances, [lp.solve_exact(inst) for inst in instances]


# ------------------------------------------------------------- build counter
def test_run_batch_builds_exactly_one_ensemble_batch(grid_with_lp):
    """All five schemes over one stage_cache pack the ensemble ONCE: the
    padded pytree is the single host->array boundary of the whole sweep
    (no per-stage re-padding), asserted via the build counter."""
    instances, sols = grid_with_lp
    cache: dict = {}
    before = eb.BUILD_COUNT
    for scheme in pipeline.PAPER_SCHEMES:
        pipeline.get_pipeline(scheme).run_batch(
            instances, lp_solutions=sols, stage_cache=cache,
            require_batch=True,
        )
    assert eb.BUILD_COUNT - before == 1
    # A rerun over the same cache (e.g. certify's reserving pass) reuses
    # the cached pytree: still zero additional builds.
    pipeline.get_pipeline("ours", discipline="reserving").run_batch(
        instances, lp_solutions=sols, stage_cache=cache
    )
    assert eb.BUILD_COUNT - before == 1


def test_run_batch_without_cache_builds_once(grid_with_lp):
    instances, sols = grid_with_lp
    before = eb.BUILD_COUNT
    pipeline.get_pipeline("ours").run_batch(instances, lp_solutions=sols)
    assert eb.BUILD_COUNT - before == 1


# ------------------------------------------------------ canonical flow table
def test_permute_flows_matches_flow_sequence_oracle():
    instances = _grid_instances()
    rng = np.random.default_rng(7)
    orders = [rng.permutation(inst.num_coflows) for inst in instances]
    batch = eb.build_ensemble_batch(instances)
    padded = batch.pad_orders(orders)
    perm = batch.permute_flows(padded)
    ends = batch.prefix_ends(padded)
    for b, (inst, order) in enumerate(zip(instances, orders)):
        mc, si, sj, sz, e = flow_sequence(inst, order)
        F, M = batch.num_flows[b], inst.num_coflows
        take = lambda a: np.take_along_axis(a, perm, axis=1)[b, :F]
        assert np.array_equal(take(batch.flow_coflow), mc)
        assert np.array_equal(take(batch.flow_src), si)
        assert np.array_equal(take(batch.flow_dst), sj)
        assert np.array_equal(take(batch.flow_size), sz)
        assert np.array_equal(ends[b, :M], e)


# ------------------------------------------------------------ order parity
def test_order_batch_parity_all_stages(grid_with_lp):
    instances, sols = grid_with_lp
    batch = eb.build_ensemble_batch(instances)
    comp = np.zeros(batch.weights.shape)
    for b, sol in enumerate(sols):
        comp[b, : instances[b].num_coflows] = sol.completion
    from repro.pipeline.stages import FifoOrder, LPOrder, WsptOrder

    got_lp = LPOrder().order_batch(batch, comp)
    got_w = WsptOrder().order_batch(batch)
    got_f = FifoOrder().order_batch(batch)
    for b, (inst, sol) in enumerate(zip(instances, sols)):
        M = inst.num_coflows
        assert np.array_equal(got_lp[b, :M], sol.order())
        assert np.array_equal(got_w[b, :M], wspt_order(inst))
        assert np.array_equal(got_f[b, :M], fifo_order(inst))
    assert LPOrder().order_batch(batch, None) is None  # must solve itself


def test_lp_solution_batch_feeds_ordering_directly():
    """EnsembleBatch.solve_lp -> LPSolutionBatch.order_batch with no
    unpadding in between, consistent with the per-instance solutions."""
    instances = _grid_instances()
    batch = eb.build_ensemble_batch(instances)
    lp_batch = batch.solve_lp(iters=150)
    orders = lp_batch.order_batch(batch.coflow_mask)
    sols = lp_batch.unpack([inst.num_coflows for inst in instances])
    for b, (inst, sol) in enumerate(zip(instances, sols)):
        M = inst.num_coflows
        assert np.array_equal(orders[b, :M], sol.order())
        # padded tail: the padded ids, stably in id order
        assert np.array_equal(
            np.sort(orders[b, M:]), np.arange(M, batch.pad_coflows)
        )


# --------------------------------------------------------- circuit arrays
@pytest.mark.parametrize("discipline", ["reserving", "greedy"])
def test_schedule_batch_arrays_matches_list_oracle(discipline, grid_with_lp):
    instances, sols = grid_with_lp
    orders = [sol.order() for sol in sols]
    batch = eb.build_ensemble_batch(instances)
    alloc_batch = allocate_batch_arrays(batch, batch.pad_orders(orders))
    allocs = alloc_batch.materialize(batch)
    ref = schedule_batch(instances, allocs, orders, discipline=discipline)
    got = schedule_batch_arrays(batch, alloc_batch, discipline=discipline)
    for (rs, rc), (gs, gc) in zip(ref, got):
        assert np.array_equal(rc, gc)
        for a, b in zip(rs, gs):
            assert np.array_equal(a.coflow, b.coflow)
            assert np.array_equal(a.establish, b.establish)
            assert np.array_equal(a.complete, b.complete)
            assert a.rate == b.rate and a.delta == b.delta


# ------------------------------------------------------- fingerprint guard
def test_stage_cache_rejects_cross_ensemble_reuse(grid_with_lp):
    instances, sols = grid_with_lp
    cache: dict = {}
    pipe = pipeline.get_pipeline("ours")
    pipe.run_batch(instances, lp_solutions=sols, stage_cache=cache)
    # Same ensemble again: fine (this is the sharing the cache exists for).
    pipe.run_batch(instances, lp_solutions=sols, stage_cache=cache)
    other = _grid_instances()
    other_sols = [lp.solve_exact(inst) for inst in other]
    with pytest.raises(ValueError, match="different ensembles"):
        pipe.run_batch(other, lp_solutions=other_sols, stage_cache=cache)
    # Same instances but different LP solutions: also a different ensemble.
    resolved = [lp.solve_exact(inst) for inst in instances]
    with pytest.raises(ValueError, match="different ensembles"):
        pipe.run_batch(instances, lp_solutions=resolved, stage_cache=cache)


def test_run_batch_mesh_must_match_cached_ensemble(grid_with_lp):
    """A cached EnsembleBatch carries its sharding; a later run_batch over
    the same cache with a different mesh must raise, not silently run
    with the cached (differently-sharded) batch."""
    from repro.launch.mesh import make_local_mesh

    instances, sols = grid_with_lp
    cache: dict = {}
    pipe = pipeline.get_pipeline("ours")
    pipe.run_batch(instances, lp_solutions=sols, stage_cache=cache)
    with pytest.raises(ValueError, match="mesh"):
        pipe.run_batch(
            instances, lp_solutions=sols, stage_cache=cache,
            mesh=make_local_mesh(),
        )
    # Consistent meshes across a cache are fine.
    mesh = make_local_mesh()
    cache2: dict = {}
    pipe.run_batch(
        instances, lp_solutions=sols, stage_cache=cache2, mesh=mesh
    )
    pipe.run_batch(
        instances, lp_solutions=sols, stage_cache=cache2, mesh=mesh
    )


def test_post_lp_build_skips_lp_arrays(grid_with_lp):
    """run_batch's internal build skips the heavy LP solver inputs (its
    LP is solved upstream); such a batch refuses to solve the LP."""
    instances, sols = grid_with_lp
    cache: dict = {}
    pipeline.get_pipeline("ours").run_batch(
        instances, lp_solutions=sols, stage_cache=cache
    )
    from repro.pipeline.pipeline import _ENSEMBLE_KEY

    cached = cache[_ENSEMBLE_KEY]
    assert not cached.has_lp_arrays
    assert cached.lp_rho.shape[1] == 0  # no (Bp, Mp, Pp) dead weight
    with pytest.raises(RuntimeError, match="with_lp_arrays"):
        cached.solve_lp(iters=10)
    # The default build keeps them (the LP phase's mode).
    assert eb.build_ensemble_batch(instances).has_lp_arrays


# ------------------------------------------------------ degenerate ensembles
def _empty_coflow_instance(num_ports=3):
    return CoflowInstance(
        demands=np.zeros((0, num_ports, num_ports)),
        weights=np.zeros(0),
        releases=np.zeros(0),
        rates=np.array([10.0, 20.0]),
        delta=1.0,
    )


def test_bucket_shape_empty_axis_regression():
    """An M=0 instance rounds to a 0-coflow bucket under a numeric
    quantum — it must NOT collide with the 'collapse to ensemble max'
    sentinel and silently inherit the ensemble maximum."""
    from repro.experiments import build_buckets

    ens = [
        _empty_coflow_instance(),
        random_instance(num_coflows=6, num_ports=3, seed=0),
    ]
    buckets = build_buckets(ens, m_quantum=8, p_quantum=8)
    by_m = {b.num_coflows: b for b in buckets}
    assert set(by_m) == {0, 8}
    assert by_m[0].indices == (0,)
    assert by_m[8].indices == (1,)
    # Collapse mode still pads everyone to the ensemble maxima.
    (one,) = build_buckets(ens, m_quantum=None, p_quantum=None)
    assert one.num_coflows == 6 and len(one) == 2


def test_degenerate_ensembles_end_to_end():
    from repro.experiments import solve_ensemble_lp, sweep

    # Entirely empty ensemble.
    assert solve_ensemble_lp([]) == []
    res = sweep([], lp_iters=50)
    assert len(res) == 0 and res.rows() == []
    # Ensemble containing an M=0 member.
    ens = [
        _empty_coflow_instance(),
        random_instance(num_coflows=6, num_ports=3, seed=0),
    ]
    sols = solve_ensemble_lp(ens, iters=50)
    assert sols[0].completion.shape == (0,)
    assert sols[0].objective == 0.0
    assert sols[1].completion.shape == (6,)
    results = pipeline.get_pipeline("ours").run_batch(
        ens, lp_solutions=sols
    )
    assert results[0].ccts.shape == (0,)
    assert results[0].total_weighted_cct == 0.0
    assert results[1].total_weighted_cct > 0


# ----------------------------------------------------------- pytree basics
def test_ensemble_batch_is_a_pytree():
    import jax

    instances = _grid_instances()[:2]
    batch = eb.build_ensemble_batch(instances)
    leaves = jax.tree.leaves(batch)
    assert leaves and all(hasattr(x, "shape") for x in leaves)
    # tree_map preserves the static metadata (instance sizes, sharding).
    mapped = jax.tree.map(lambda x: x, batch)
    assert mapped.num_coflows == batch.num_coflows
    assert mapped.num_instances == batch.num_instances


def test_allocation_batch_prefix_lb_matches_oracle(grid_with_lp):
    from repro.core.allocation import allocate

    instances, sols = grid_with_lp
    orders = [sol.order() for sol in sols]
    batch = eb.build_ensemble_batch(instances)
    ab = allocate_batch_arrays(batch, batch.pad_orders(orders))
    for b, (inst, order) in enumerate(zip(instances, orders)):
        ref = allocate(inst, order)
        M = inst.num_coflows
        assert np.array_equal(ab.prefix_lb[b, :M], ref.prefix_lb)
        assert np.array_equal(
            ab.core[b, : batch.num_flows[b]], ref.core
        )
